"""gemma3-12b [dense] — 48L d_model=3840 16H (GQA kv=8) d_ff=15360
vocab=262144, 5:1 local:global sliding-window attention, 128k context.
[hf:google/gemma-3-1b-pt family; dims per assignment]

The 5:1 interleave is one pattern block of 5 sliding-window layers followed
by one global layer; 48 layers = 8 scanned blocks.  Because of the sliding
window, this arch runs ``long_500k`` (local KV caches are bounded at the
window; global layers hold the full cache, O(S) per decoded token) — see
DESIGN.md §4.
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="gemma3-12b",
    family="dense",
    num_layers=48,
    d_model=3840,
    num_heads=16,
    num_kv_heads=8,
    head_dim=256,
    d_ff=15360,
    vocab_size=262144,
    pattern=("attn_local",) * 5 + ("attn",),
    sliding_window=1024,
    rope_theta=1_000_000.0,
    tie_embeddings=True,
    dtype="bfloat16",
    source="hf:google/gemma-3 family (assigned dims); 5:1 local:global per Gemma 3 report",
)

REDUCED = ModelConfig(
    name="gemma3-12b-reduced",
    family="dense",
    num_layers=2,
    d_model=256,
    num_heads=4,
    num_kv_heads=2,
    head_dim=64,
    d_ff=512,
    vocab_size=512,
    pattern=("attn_local", "attn"),
    sliding_window=16,
    tie_embeddings=True,
    dtype="float32",
    source="reduced smoke variant",
)
