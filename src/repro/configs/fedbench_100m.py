"""fedbench-100m — paper-proxy LLaVA-style prefix VLM (~100M params) used by
the end-to-end federated fine-tuning example (examples/federated_finetune.py).

Stands in for LLaVA-1.5-7B, which cannot be fetched in this container: same
topology (decoder LM consuming projected image-patch prefix embeddings, LoRA
on attention q/v), scaled to train a few hundred steps on CPU.
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="fedbench-100m",
    family="vlm",
    num_layers=12,
    d_model=768,
    num_heads=12,
    num_kv_heads=4,
    head_dim=64,
    d_ff=3072,
    vocab_size=256,        # synthetic task vocab
    tie_embeddings=True,
    vision_dim=32,         # synthetic patch-embedding dim
    num_vision_tokens=8,
    vision_mode="prefix",
    dtype="float32",
    source="paper-proxy bench model (LLaVA-1.5 stand-in, DESIGN.md §1)",
)

REDUCED = ModelConfig(
    name="fedbench-100m-reduced",
    family="vlm",
    num_layers=2,
    d_model=128,
    num_heads=4,
    num_kv_heads=2,
    head_dim=32,
    d_ff=256,
    vocab_size=256,
    tie_embeddings=True,
    vision_dim=32,
    num_vision_tokens=8,
    vision_mode="prefix",
    dtype="float32",
    source="reduced smoke variant",
)
