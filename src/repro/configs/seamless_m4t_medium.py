"""seamless-m4t-medium [audio] — enc-dec, 12L d_model=1024 16H (kv=16)
d_ff=4096 vocab=256206, multimodal. [arXiv:2308.11596]

Transformer backbone only (assignment carve-out): the mel-spectrogram +
conv feature extractor is a stub — ``input_specs()`` provides precomputed
audio frame embeddings [B, S/4, 1024] consumed by a 12-layer bidirectional
encoder; the 12-layer decoder self-attends causally and cross-attends to the
encoder output.  LoRA attaches to encoder self-attn q/v and decoder self- &
cross-attn q/v.
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-medium",
    family="encdec",
    num_layers=12,          # decoder depth
    encoder_layers=12,
    d_model=1024,
    num_heads=16,
    num_kv_heads=16,
    head_dim=64,
    d_ff=4096,
    vocab_size=256206,
    tie_embeddings=True,
    audio_dim=1024,
    dtype="bfloat16",
    source="arXiv:2308.11596 (SeamlessM4T medium)",
)

REDUCED = ModelConfig(
    name="seamless-m4t-reduced",
    family="encdec",
    num_layers=2,
    encoder_layers=2,
    d_model=128,
    num_heads=4,
    num_kv_heads=4,
    head_dim=32,
    d_ff=256,
    vocab_size=512,
    tie_embeddings=True,
    audio_dim=64,
    dtype="float32",
    source="reduced smoke variant",
)
