"""mamba2-130m [ssm] — 24L d_model=768, attention-free, d_ff=0,
vocab=50280, ssm_state=128 — SSD (state-space duality). [arXiv:2405.21060]

Pure Mamba-2 stack: every layer is an SSD block (expand=2 → d_inner=1536,
head_dim=64 → 24 SSD heads), no separate FFN (d_ff=0).  Decode state is O(1)
in sequence length, so this arch runs ``long_500k``.

FediLoRA applicability (DESIGN.md §Arch-applicability): the paper targets
attention q/v projections, which do not exist here; LoRA attaches to the
SSD block's in/out projections instead — the aggregation and editing operate
on those adapters unchanged.
"""

from repro.models.config import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="mamba2-130m",
    family="ssm",
    num_layers=24,
    d_model=768,
    num_heads=12,        # unused (attention-free); kept for config uniformity
    num_kv_heads=12,
    d_ff=0,
    vocab_size=50280,
    tie_embeddings=True,
    pattern=("mamba",),
    ssm=SSMConfig(state_dim=128, head_dim=64, expand=2, conv_width=4, chunk_size=256),
    dtype="bfloat16",
    source="arXiv:2405.21060 (Mamba-2), 130m config",
)

REDUCED = ModelConfig(
    name="mamba2-130m-reduced",
    family="ssm",
    num_layers=2,
    d_model=128,
    num_heads=2,
    num_kv_heads=2,
    d_ff=0,
    vocab_size=512,
    tie_embeddings=True,
    pattern=("mamba",),
    ssm=SSMConfig(state_dim=16, head_dim=32, expand=2, conv_width=4, chunk_size=32),
    dtype="float32",
    source="reduced smoke variant",
)
