"""deepseek-v2-236b [moe] — 60L d_model=5120 128H, MLA kv_lora=512,
MoE 160 routed experts top-6 + 2 shared, expert d_ff=1536, vocab=102400.
[arXiv:2405.04434]

Multi-head Latent Attention: KV compressed to a 512-dim latent (+64-dim
shared RoPE key); decode uses the absorbed-weight path over the *compressed*
cache (repro.models.layers.mla_decode).  q_lora_rank=1536 per the paper.

Note: DeepSeek-V2's first layer is dense-FFN; we instantiate all 60 layers
as MoE (uniform scan block) — a <0.5% parameter deviation recorded here and
in DESIGN.md.
"""

from repro.models.config import ModelConfig, MLAConfig, MoEConfig

CONFIG = ModelConfig(
    name="deepseek-v2-236b",
    family="moe",
    num_layers=60,
    d_model=5120,
    num_heads=128,
    num_kv_heads=128,     # MLA: heads share the compressed latent
    d_ff=12288,           # (dense-layer width; unused — all layers MoE here)
    vocab_size=102400,
    tie_embeddings=False,
    mla=MLAConfig(kv_lora_rank=512, q_lora_rank=1536,
                  qk_nope_head_dim=128, qk_rope_head_dim=64, v_head_dim=128),
    moe=MoEConfig(num_experts=160, experts_per_token=6, d_ff_expert=1536,
                  num_shared_experts=2, d_ff_shared=1536),
    dtype="bfloat16",
    source="arXiv:2405.04434 (DeepSeek-V2)",
)

REDUCED = ModelConfig(
    name="deepseek-v2-reduced",
    family="moe",
    num_layers=2,
    d_model=256,
    num_heads=4,
    num_kv_heads=4,
    d_ff=512,
    vocab_size=512,
    tie_embeddings=True,
    mla=MLAConfig(kv_lora_rank=64, q_lora_rank=48,
                  qk_nope_head_dim=32, qk_rope_head_dim=16, v_head_dim=32),
    moe=MoEConfig(num_experts=4, experts_per_token=2, d_ff_expert=256,
                  num_shared_experts=1, d_ff_shared=256),
    dtype="float32",
    source="reduced smoke variant",
)
